"""Cost model (paper §4.3) adapted to TPU.

Two evaluation paths, as in the paper:

* **model-based** — fast analytic score used for most patterns:
      f(P) = M(V_saved) + (N-1) * phi
  where M(V) extrapolates the latency of moving V bytes through HBM using an
  offline bandwidth-utilization curve (paper Fig. 4: small transfers do not
  saturate the memory system), and phi is the per-kernel dispatch overhead.

* **execution-based** — measure the generated kernel directly:
      f(P) = sum_j K(Op_j) + (N-1) * phi - K(P)
  On this CPU container "execution" means timing the interpret-mode Pallas
  kernel / jitted reference, which preserves *relative* ordering for the
  plan-selection decisions the paper makes with it; the tuner (Alg. 3) uses
  it for the complex-pattern class exactly as §4.3 prescribes.

Hardware presets: ``V100`` validates the cost model against the paper's own
environment; ``TPU_V5E`` is the deployment target used everywhere else.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .ir import Graph, OpKind
from .pattern import FusionPattern

__all__ = ["HardwareModel", "V100", "TPU_V5E", "CostModel", "PatternScore"]


@dataclass(frozen=True)
class HardwareModel:
    name: str
    hbm_bw: float            # bytes/s, peak
    peak_flops: float        # FLOP/s (matmul-precision)
    launch_latency: float    # phi, seconds per kernel dispatch
    onchip_budget: int       # bytes of scratch (GPU shared mem / TPU VMEM)
    # bandwidth-utilization curve (paper Fig. 4): transfer of V bytes runs at
    # eff(V) * hbm_bw.  Modeled as a saturating curve with half-utilization
    # point `bw_half` bytes, calibrated offline.
    bw_half: float = 1 << 17
    # interconnect for the roofline/collective term (per-chip, all links)
    ici_bw: float = 0.0
    # per-kernel register/VREG live-value budget (paper §4.3's occupancy
    # loss): the stitched emitter holds every live internal intermediate of
    # the current row block in vector registers, so a pattern whose peak
    # live working set exceeds this budget would spill / serialise the
    # pipeline — the cost model rejects it as *infeasible*, not merely
    # unattractive, which is what forces over-wide independent regions to
    # shatter into FFD packs instead of one monolithic kernel.
    reg_budget: int = 2 * 1024 * 1024

    def efficiency(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 1.0
        return nbytes / (nbytes + self.bw_half)

    def mem_time(self, nbytes: float) -> float:
        """M(V): latency to move V bytes at utilization-scaled bandwidth."""
        if nbytes <= 0:
            return 0.0
        return nbytes / (self.hbm_bw * self.efficiency(nbytes))

    def flops_time(self, flops: float) -> float:
        return flops / self.peak_flops if flops > 0 else 0.0


V100 = HardwareModel(
    name="V100",
    hbm_bw=900e9,
    peak_flops=15.7e12,          # fp32 FMA; the paper's workloads are fp32
    launch_latency=8e-6,         # paper: phi between 6 and 10 us
    onchip_budget=96 * 1024,     # shared memory per SM (opt-in 96KB on Volta)
    bw_half=1 << 18,
    ici_bw=150e9,                # NVLink aggregate (unused by fusion scoring)
    reg_budget=256 * 1024,       # 64K 32-bit registers per SM
)

TPU_V5E = HardwareModel(
    name="TPU_V5E",
    hbm_bw=819e9,
    peak_flops=197e12,           # bf16
    launch_latency=2e-6,         # XLA static-schedule dispatch, no driver
    onchip_budget=16 * 1024 * 1024,  # conservative usable VMEM scratch
    bw_half=1 << 17,
    ici_bw=3 * 2 * 50e9,         # 3 links x 2 directions x 50 GB/s
    reg_budget=2 * 1024 * 1024,  # VREG + low-latency VMEM working set
)


@dataclass
class PatternScore:
    pattern: FusionPattern
    score: float               # seconds saved; the ILP objective weight f(P)
    feasible: bool
    reason: str = ""
    scratch_request: int = 0   # worst-case on-chip bytes before Alg.4 reuse
    saved_bytes: int = 0
    kernels_removed: int = 0
    reg_request: int = 0       # peak live register bytes (occupancy gate)


class CostModel:
    """Scores fusion patterns; enforces the paper's feasibility gates.

    ``reg_budget`` overrides the hardware's register/live-value budget
    (``GenConfig.reg_budget`` threads through here); None keeps the
    hardware default."""

    def __init__(self, hw: HardwareModel = TPU_V5E,
                 reg_budget: int | None = None):
        self.hw = hw
        self.reg_budget = hw.reg_budget if reg_budget is None else reg_budget

    # -- per-op kernel-time model -------------------------------------------
    def op_bytes(self, g: Graph, name: str) -> int:
        node = g[name]
        in_b = sum(g[o].bytes for o in node.operands)
        return in_b + node.bytes

    def gemm_flops(self, g: Graph, name: str) -> float:
        node = g[name]
        if node.kind not in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            return 0.0
        lhs = g[node.operands[0]]
        k = math.prod(lhs.shape[d] for d in node.attrs["contract"][0])
        return 2.0 * node.size * k

    def op_flops(self, g: Graph, name: str) -> float:
        """MXU/compute FLOPs of one op: GEMMs by contraction size, registered
        custom kernels by their declared estimate, everything else 0."""
        node = g[name]
        if node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            return self.gemm_flops(g, name)
        if node.kind is OpKind.CUSTOM and "project" not in node.attrs:
            from repro.kernels.registry import lookup
            desc = lookup(node)
            if desc is not None:
                return desc.flops(node, g)
        return 0.0

    def custom_scratch(self, p: FusionPattern) -> int:
        """On-chip bytes the pattern's registered custom-kernel bodies bring
        along (e.g. flash attention's m/l/acc accumulators).  Kept separate
        from :meth:`scratch_request` because that dict feeds the *template*
        scratch plan; a custom kernel allocates its own scratch inside its
        saved body."""
        from repro.kernels.registry import lookup
        total = 0
        for n in p.compute_members:
            if n.kind is OpKind.CUSTOM and "project" not in n.attrs:
                desc = lookup(n)
                if desc is not None:
                    total += desc.scratch_bytes(n, p.graph)
        return total

    def kernel_time(self, g: Graph, name: str) -> float:
        """K(Op): standalone kernel execution time for one op (roofline max
        of its memory and compute terms) — the unfused baseline cost."""
        node = g[name]
        if node.is_source() or node.kind is OpKind.TUPLE:
            return 0.0
        mem = self.hw.mem_time(self.op_bytes(g, name))
        comp = 0.0
        if node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM, OpKind.CUSTOM):
            comp = self.hw.flops_time(self.op_flops(g, name))
        elif node.kind is OpKind.REDUCTION:
            comp = self.hw.flops_time(float(g[node.operands[0]].size))
        elif node.kind is OpKind.ELEMENTWISE:
            comp = self.hw.flops_time(float(node.size) * max(1, len(node.operands)))
        return max(mem, comp)

    def fused_time(self, p: FusionPattern) -> float:
        """K(P): modeled execution of the fused kernel — external I/O moves
        through HBM once; internal edges live on-chip; compute unchanged."""
        g = p.graph
        io_bytes = p.input_bytes + p.output_bytes
        mem = self.hw.mem_time(io_bytes)
        comp = 0.0
        for n in p.compute_members:
            if n.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM, OpKind.CUSTOM):
                comp += self.hw.flops_time(self.op_flops(g, n.name))
            else:
                comp += self.hw.flops_time(float(n.size))
        return max(mem, comp)

    # -- scratch requirement (pre-Alg.4) ------------------------------------
    def scratch_request(self, p: FusionPattern) -> dict[str, int]:
        """Bytes of on-chip transfer storage each member would request.

        Mirrors §5: intermediates crossing a *composition boundary* (produced
        by a reduction/gemm member, or consumed by one) need block-level
        scratch (GPU shared / TPU VMEM); pure elementwise chains stay in
        registers (VREG) and request nothing.
        """
        g = p.graph
        req: dict[str, int] = {}
        heavy = {OpKind.REDUCTION, OpKind.GEMM, OpKind.BATCHED_GEMM}
        for n in p.compute_members:
            internal_users = [u for u in g.users(n.name) if u in p.members]
            if not internal_users:
                continue
            crosses = n.kind in heavy or any(g[u].kind in heavy for u in internal_users)
            if crosses:
                # per-block tile of the intermediate: bounded by one row-block
                # (minor-most dim x 8 sublanes) or the whole tensor if small
                tile = min(n.bytes, self._tile_bytes(n))
                req[n.name] = tile
        return req

    def _tile_bytes(self, node) -> int:
        """One (8, minor) VMEM tile of the tensor (the per-block working set a
        block-composition schedule holds on-chip at a time)."""
        if not node.shape:
            return node.bytes
        minor = node.shape[-1]
        rows = 8 if len(node.shape) > 1 else 1
        return minor * rows * (node.bytes // max(node.size, 1))

    # -- register pressure (§4.3 occupancy gate) ------------------------------
    def register_pressure(self, p: FusionPattern) -> int:
        """Peak live-value bytes of one row block through the stitched body.

        The emitter evaluates members in topo order holding every internal
        intermediate of the current row block as a live vector value; a
        value dies after its last in-pattern consumer.  Wide *independent*
        regions (interleaved per-expert MoE chains) keep one working set
        per chain live simultaneously, so their peak grows with the number
        of chains — the occupancy loss the paper trades against launch
        savings.  Patterns over :attr:`reg_budget` are infeasible; the FFD
        packer re-forms the chains into bins that fit.
        """
        g = p.graph
        member_groups = getattr(p, "member_groups", None)
        if member_groups:
            # horizontal pack: member subgraphs are independent and laid out
            # along the kernel's grid dimension (one block range each), so
            # the per-block live working set is the *widest* subgraph — not
            # the interleaved sum.  This is the §4.2 occupancy argument: a
            # pack shares one launch without inflating per-block registers,
            # which an interleaved monolithic fusion cannot avoid.
            return max(
                self.register_pressure(FusionPattern(g, grp, "pack-member"))
                for grp in member_groups
            )
        seq = p.compute_members
        if len(seq) < 2:
            return 0
        counts: dict[int, float] = {}
        for name in p.external_outputs:
            shp = g[name].shape
            if shp and shp[0] > 1:
                counts[shp[0]] = counts.get(shp[0], 0.0) + 1000.0
        for name in p.external_inputs:
            shp = g[name].shape
            if shp and shp[0] > 1:
                counts[shp[0]] = counts.get(shp[0], 0.0) + 1.0
        if not counts:
            return 0
        rows = max(counts, key=lambda k: (counts[k], k))
        rb = min(8, rows)
        # single-block patterns (registered-custom replay; cross-row
        # accumulators feeding members, e.g. the packed optimizer's global
        # grad-norm) run as grid==1 composition: whole-array residency is
        # the scratch plan's domain, and with one block in flight there is
        # no occupancy to lose — the register gate only prices row-streamed
        # interleaving width
        members = set(p.members)
        for n in seq:
            if n.kind is OpKind.CUSTOM and "project" not in n.attrs:
                return 0
            if n.kind is OpKind.REDUCTION and 0 in tuple(n.attrs.get("axes", ())):
                src = g[n.operands[0]]
                if src.shape and src.shape[0] == rows and any(
                        u in members for u in g.users(n.name)):
                    return 0

        def tile(node) -> int:
            shp = node.shape
            if shp and shp[0] == rows:
                return (node.bytes // rows) * rb
            # not tiled by the row grid (weight converts, transposed
            # operands): streamed through one (8, minor) tile at a time
            return min(node.bytes, self._tile_bytes(node))

        pos = {n.name: i for i, n in enumerate(seq)}
        last_use: dict[str, int] = {}
        for n in seq:
            for o in n.operands:
                if o in pos:
                    last_use[o] = max(last_use.get(o, -1), pos[n.name])
        live = 0
        peak = 0
        expiry: dict[int, list[int]] = {}
        for i, n in enumerate(seq):
            b = tile(n)
            if n.name in last_use:
                live += b
                expiry.setdefault(last_use[n.name], []).append(b)
                peak = max(peak, live)
            else:
                peak = max(peak, live + b)  # transient: streamed straight out
            for dead in expiry.pop(i, ()):
                live -= dead
        return peak

    # -- the paper's two scoring paths ---------------------------------------
    def score_model_based(self, p: FusionPattern) -> PatternScore:
        n_kernels = len(p.compute_members)
        if n_kernels < 2:
            return PatternScore(p, -1.0, False, "singleton", 0, 0, 0)
        req = self.scratch_request(p)
        total_req = sum(req.values()) + self.custom_scratch(p)
        if total_req > self.hw.onchip_budget:
            return PatternScore(
                p, -1.0, False,
                f"scratch {total_req}B exceeds budget {self.hw.onchip_budget}B",
                total_req, 0, 0,
            )
        reg = self.register_pressure(p)
        if reg > self.reg_budget:
            return PatternScore(
                p, -1.0, False,
                f"register pressure {reg}B exceeds budget {self.reg_budget}B",
                total_req, 0, 0, reg,
            )
        saved = p.saved_bytes
        score = self.hw.mem_time(saved) + (n_kernels - 1) * self.hw.launch_latency
        return PatternScore(p, score, True, "model", total_req, saved,
                            n_kernels - 1, reg)

    def score_execution_based(self, p: FusionPattern, measured_fused: float | None = None) -> PatternScore:
        n_kernels = len(p.compute_members)
        if n_kernels < 2:
            return PatternScore(p, -1.0, False, "singleton", 0, 0, 0)
        req = self.scratch_request(p)
        total_req = sum(req.values()) + self.custom_scratch(p)
        if total_req > self.hw.onchip_budget:
            return PatternScore(p, -1.0, False, "scratch over budget", total_req, 0, 0)
        reg = self.register_pressure(p)
        if reg > self.reg_budget:
            return PatternScore(
                p, -1.0, False,
                f"register pressure {reg}B exceeds budget {self.reg_budget}B",
                total_req, 0, 0, reg,
            )
        unfused = sum(self.kernel_time(p.graph, n.name) for n in p.compute_members)
        fused = measured_fused if measured_fused is not None else self.fused_time(p)
        score = unfused + (n_kernels - 1) * self.hw.launch_latency - fused
        feasible = score >= 0
        return PatternScore(
            p, score, feasible, "execution", total_req, p.saved_bytes,
            n_kernels - 1, reg
        )

    # -- dispatch rule (§4.3: model-based for most, execution for complex) ---
    def score(self, p: FusionPattern) -> PatternScore:
        complex_pattern = (p.pattern_class == "gemm"
                           or len(p.reduce_kinds) > 1
                           or bool(p.custom_members))
        if complex_pattern:
            return self.score_execution_based(p)
        return self.score_model_based(p)
