"""Cost model (paper §4.3) adapted to TPU.

Two evaluation paths, as in the paper:

* **model-based** — fast analytic score used for most patterns:
      f(P) = M(V_saved) + (N-1) * phi
  where M(V) extrapolates the latency of moving V bytes through HBM using an
  offline bandwidth-utilization curve (paper Fig. 4: small transfers do not
  saturate the memory system), and phi is the per-kernel dispatch overhead.

* **execution-based** — measure the generated kernel directly:
      f(P) = sum_j K(Op_j) + (N-1) * phi - K(P)
  On this CPU container "execution" means timing the interpret-mode Pallas
  kernel / jitted reference, which preserves *relative* ordering for the
  plan-selection decisions the paper makes with it; the tuner (Alg. 3) uses
  it for the complex-pattern class exactly as §4.3 prescribes.

Hardware presets: ``V100`` validates the cost model against the paper's own
environment; ``TPU_V5E`` is the deployment target used everywhere else.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .ir import Graph, OpKind
from .pattern import FusionPattern

__all__ = ["HardwareModel", "V100", "TPU_V5E", "CostModel", "PatternScore"]


@dataclass(frozen=True)
class HardwareModel:
    name: str
    hbm_bw: float            # bytes/s, peak
    peak_flops: float        # FLOP/s (matmul-precision)
    launch_latency: float    # phi, seconds per kernel dispatch
    onchip_budget: int       # bytes of scratch (GPU shared mem / TPU VMEM)
    # bandwidth-utilization curve (paper Fig. 4): transfer of V bytes runs at
    # eff(V) * hbm_bw.  Modeled as a saturating curve with half-utilization
    # point `bw_half` bytes, calibrated offline.
    bw_half: float = 1 << 17
    # interconnect for the roofline/collective term (per-chip, all links)
    ici_bw: float = 0.0

    def efficiency(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 1.0
        return nbytes / (nbytes + self.bw_half)

    def mem_time(self, nbytes: float) -> float:
        """M(V): latency to move V bytes at utilization-scaled bandwidth."""
        if nbytes <= 0:
            return 0.0
        return nbytes / (self.hbm_bw * self.efficiency(nbytes))

    def flops_time(self, flops: float) -> float:
        return flops / self.peak_flops if flops > 0 else 0.0


V100 = HardwareModel(
    name="V100",
    hbm_bw=900e9,
    peak_flops=15.7e12,          # fp32 FMA; the paper's workloads are fp32
    launch_latency=8e-6,         # paper: phi between 6 and 10 us
    onchip_budget=96 * 1024,     # shared memory per SM (opt-in 96KB on Volta)
    bw_half=1 << 18,
    ici_bw=150e9,                # NVLink aggregate (unused by fusion scoring)
)

TPU_V5E = HardwareModel(
    name="TPU_V5E",
    hbm_bw=819e9,
    peak_flops=197e12,           # bf16
    launch_latency=2e-6,         # XLA static-schedule dispatch, no driver
    onchip_budget=16 * 1024 * 1024,  # conservative usable VMEM scratch
    bw_half=1 << 17,
    ici_bw=3 * 2 * 50e9,         # 3 links x 2 directions x 50 GB/s
)


@dataclass
class PatternScore:
    pattern: FusionPattern
    score: float               # seconds saved; the ILP objective weight f(P)
    feasible: bool
    reason: str = ""
    scratch_request: int = 0   # worst-case on-chip bytes before Alg.4 reuse
    saved_bytes: int = 0
    kernels_removed: int = 0


class CostModel:
    """Scores fusion patterns; enforces the paper's feasibility gates."""

    def __init__(self, hw: HardwareModel = TPU_V5E):
        self.hw = hw

    # -- per-op kernel-time model -------------------------------------------
    def op_bytes(self, g: Graph, name: str) -> int:
        node = g[name]
        in_b = sum(g[o].bytes for o in node.operands)
        return in_b + node.bytes

    def gemm_flops(self, g: Graph, name: str) -> float:
        node = g[name]
        if node.kind not in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            return 0.0
        lhs = g[node.operands[0]]
        k = math.prod(lhs.shape[d] for d in node.attrs["contract"][0])
        return 2.0 * node.size * k

    def op_flops(self, g: Graph, name: str) -> float:
        """MXU/compute FLOPs of one op: GEMMs by contraction size, registered
        custom kernels by their declared estimate, everything else 0."""
        node = g[name]
        if node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM):
            return self.gemm_flops(g, name)
        if node.kind is OpKind.CUSTOM and "project" not in node.attrs:
            from repro.kernels.registry import lookup
            desc = lookup(node)
            if desc is not None:
                return desc.flops(node, g)
        return 0.0

    def custom_scratch(self, p: FusionPattern) -> int:
        """On-chip bytes the pattern's registered custom-kernel bodies bring
        along (e.g. flash attention's m/l/acc accumulators).  Kept separate
        from :meth:`scratch_request` because that dict feeds the *template*
        scratch plan; a custom kernel allocates its own scratch inside its
        saved body."""
        from repro.kernels.registry import lookup
        total = 0
        for n in p.compute_members:
            if n.kind is OpKind.CUSTOM and "project" not in n.attrs:
                desc = lookup(n)
                if desc is not None:
                    total += desc.scratch_bytes(n, p.graph)
        return total

    def kernel_time(self, g: Graph, name: str) -> float:
        """K(Op): standalone kernel execution time for one op (roofline max
        of its memory and compute terms) — the unfused baseline cost."""
        node = g[name]
        if node.is_source() or node.kind is OpKind.TUPLE:
            return 0.0
        mem = self.hw.mem_time(self.op_bytes(g, name))
        comp = 0.0
        if node.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM, OpKind.CUSTOM):
            comp = self.hw.flops_time(self.op_flops(g, name))
        elif node.kind is OpKind.REDUCTION:
            comp = self.hw.flops_time(float(g[node.operands[0]].size))
        elif node.kind is OpKind.ELEMENTWISE:
            comp = self.hw.flops_time(float(node.size) * max(1, len(node.operands)))
        return max(mem, comp)

    def fused_time(self, p: FusionPattern) -> float:
        """K(P): modeled execution of the fused kernel — external I/O moves
        through HBM once; internal edges live on-chip; compute unchanged."""
        g = p.graph
        io_bytes = p.input_bytes + p.output_bytes
        mem = self.hw.mem_time(io_bytes)
        comp = 0.0
        for n in p.compute_members:
            if n.kind in (OpKind.GEMM, OpKind.BATCHED_GEMM, OpKind.CUSTOM):
                comp += self.hw.flops_time(self.op_flops(g, n.name))
            else:
                comp += self.hw.flops_time(float(n.size))
        return max(mem, comp)

    # -- scratch requirement (pre-Alg.4) ------------------------------------
    def scratch_request(self, p: FusionPattern) -> dict[str, int]:
        """Bytes of on-chip transfer storage each member would request.

        Mirrors §5: intermediates crossing a *composition boundary* (produced
        by a reduction/gemm member, or consumed by one) need block-level
        scratch (GPU shared / TPU VMEM); pure elementwise chains stay in
        registers (VREG) and request nothing.
        """
        g = p.graph
        req: dict[str, int] = {}
        heavy = {OpKind.REDUCTION, OpKind.GEMM, OpKind.BATCHED_GEMM}
        for n in p.compute_members:
            internal_users = [u for u in g.users(n.name) if u in p.members]
            if not internal_users:
                continue
            crosses = n.kind in heavy or any(g[u].kind in heavy for u in internal_users)
            if crosses:
                # per-block tile of the intermediate: bounded by one row-block
                # (minor-most dim x 8 sublanes) or the whole tensor if small
                tile = min(n.bytes, self._tile_bytes(n))
                req[n.name] = tile
        return req

    def _tile_bytes(self, node) -> int:
        """One (8, minor) VMEM tile of the tensor (the per-block working set a
        block-composition schedule holds on-chip at a time)."""
        if not node.shape:
            return node.bytes
        minor = node.shape[-1]
        rows = 8 if len(node.shape) > 1 else 1
        return minor * rows * (node.bytes // max(node.size, 1))

    # -- the paper's two scoring paths ---------------------------------------
    def score_model_based(self, p: FusionPattern) -> PatternScore:
        n_kernels = len(p.compute_members)
        if n_kernels < 2:
            return PatternScore(p, -1.0, False, "singleton", 0, 0, 0)
        req = self.scratch_request(p)
        total_req = sum(req.values()) + self.custom_scratch(p)
        if total_req > self.hw.onchip_budget:
            return PatternScore(
                p, -1.0, False,
                f"scratch {total_req}B exceeds budget {self.hw.onchip_budget}B",
                total_req, 0, 0,
            )
        saved = p.saved_bytes
        score = self.hw.mem_time(saved) + (n_kernels - 1) * self.hw.launch_latency
        return PatternScore(p, score, True, "model", total_req, saved, n_kernels - 1)

    def score_execution_based(self, p: FusionPattern, measured_fused: float | None = None) -> PatternScore:
        n_kernels = len(p.compute_members)
        if n_kernels < 2:
            return PatternScore(p, -1.0, False, "singleton", 0, 0, 0)
        req = self.scratch_request(p)
        total_req = sum(req.values()) + self.custom_scratch(p)
        if total_req > self.hw.onchip_budget:
            return PatternScore(p, -1.0, False, "scratch over budget", total_req, 0, 0)
        unfused = sum(self.kernel_time(p.graph, n.name) for n in p.compute_members)
        fused = measured_fused if measured_fused is not None else self.fused_time(p)
        score = unfused + (n_kernels - 1) * self.hw.launch_latency - fused
        feasible = score >= 0
        return PatternScore(
            p, score, feasible, "execution", total_req, p.saved_bytes, n_kernels - 1
        )

    # -- dispatch rule (§4.3: model-based for most, execution for complex) ---
    def score(self, p: FusionPattern) -> PatternScore:
        complex_pattern = (p.pattern_class == "gemm"
                           or len(p.reduce_kinds) > 1
                           or bool(p.custom_members))
        if complex_pattern:
            return self.score_execution_based(p)
        return self.score_model_based(p)
