"""StitchCompiler — the public optimize-and-execute API (paper Fig. 2).

Pipeline:   graph -> pattern generation (§4.2) -> cost scoring (§4.3)
          -> ILP + cycle cuts (§4.1) -> per-group kernel tuning (Alg. 3)
          -> executable.

Three execution modes reproduce the paper's comparison axes:

* ``mode="off"``    — one kernel per op ("TensorFlow" baseline),
* ``mode="xla"``    — XLA-style fusion: connected elementwise/row-reduction
                      chains only, no packing, no gemm stitching,
* ``mode="stitch"`` — full FusionStitching.

The compiled object reports the statistics the paper's tables are built
from: kernel counts per mode (Table 3's compression ratios), modeled step
times (Table 3 speedups), pattern-class composition (Fig. 6), and scratch
allocation statistics (Table 4).
"""

from __future__ import annotations

import math
import time as _time
from dataclasses import dataclass, field
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro import obs

from .codegen import build_reference_fn, eval_node
from .cost import CostModel, HardwareModel, PatternScore, TPU_V5E
from .fusiongen import GenConfig, generate_patterns, substitution_fusion
from .ilp import PlanResult, solve_fusion_plan
from .ir import Graph, OpKind
from .pattern import FusionPattern
from .scratch import ScratchPlan
from .tuner import TemplateTuner, TunedKernel

__all__ = ["StitchCompiler", "CompiledGraph", "FusionStats", "xla_like_groups"]


# ---------------------------------------------------------------------------
# XLA-baseline grouping (thread composition only)
# ---------------------------------------------------------------------------

_XLA_FUSIBLE = {
    OpKind.ELEMENTWISE,
    OpKind.BROADCAST,
    OpKind.RESHAPE,
    OpKind.TRANSPOSE,
    OpKind.SLICE,
}


def xla_like_groups(g: Graph) -> list[frozenset[str]]:
    """Greedy XLA-ish loop fusion: a producer is fused into its consumer when
    the producer is elementwise glue and *all* of its users land in the same
    group (duplication-free single-output fusion); row reductions may root a
    group (input fusion).  No packing of independent ops, no gemm members —
    exactly the capability gap the paper exploits (§1, §7)."""
    group_of: dict[str, int] = {}
    groups: dict[int, set[str]] = {}
    opaque: dict[int, bool] = {}   # group rooted at a non-loop op (gemm etc.)
    nxt = 0
    # walk reverse-topo: consumers first
    for name in reversed(g.topo_order()):
        node = g[name]
        if node.is_source() or node.kind is OpKind.TUPLE:
            continue
        fusible = node.kind in _XLA_FUSIBLE or (
            node.kind is OpKind.REDUCTION and node.reduce_kind.value == "row"
        )
        placed = False
        if fusible and name not in g.outputs:
            users = [u for u in g.users(name) if not g[u].is_source()]
            ugroups = {group_of.get(u) for u in users}
            if len(ugroups) == 1 and None not in ugroups and users:
                gid = ugroups.pop()
                # loop fusion only merges into loop-fusion groups — never
                # into a GEMM/custom kernel — and reductions stay roots.
                if node.kind is not OpKind.REDUCTION and not opaque[gid]:
                    groups[gid].add(name)
                    group_of[name] = gid
                    placed = True
        if not placed:
            groups[nxt] = {name}
            group_of[name] = nxt
            opaque[nxt] = not fusible and node.kind is not OpKind.REDUCTION
            nxt += 1
    return [frozenset(v) for v in groups.values()]


# ---------------------------------------------------------------------------
# compiled artifact
# ---------------------------------------------------------------------------

@dataclass
class FusionStats:
    mode: str
    n_ops: int                       # compute ops in the graph ("TF kernels")
    n_kernels: int                   # kernels after this mode's fusion
    pattern_classes: dict[str, int] = field(default_factory=dict)
    modeled_time: float = 0.0        # cost-model step time, seconds
    scratch_requested: int = 0
    scratch_allocated: int = 0
    patterns_with_scratch: int = 0
    pallas_groups: int = 0           # groups executed as stitched Pallas
    packs: int = 0                   # horizontal PackPatterns in the plan
    packed_subgraphs: int = 0        # independent subgraphs absorbed by packs
    ilp: PlanResult | None = None
    cache_status: str = "off"        # "off" | "miss" | "hit"
    compile_seconds: float = 0.0     # wall time spent producing this artifact
    # static verification summary ({"errors", "warnings", "codes"}) when the
    # compiler ran with verify != "off"; None when verification was skipped
    verify: dict | None = None
    verify_seconds: float = 0.0      # wall time of the verification passes
    # structured StitchInfeasible diagnostics from tuning: why a chosen
    # pattern degraded to a fused-jnp group instead of a Pallas kernel
    diagnostics: list = field(default_factory=list)

    @property
    def compression(self) -> float:
        return self.n_ops / self.n_kernels if self.n_kernels else float("nan")

    @property
    def alloc_over_req(self) -> float:
        if not self.scratch_requested:
            return 1.0
        return self.scratch_allocated / self.scratch_requested


@dataclass
class _Group:
    members: frozenset[str]
    kind: str                        # "pallas" | "jnp" | "op"
    tuned: TunedKernel | None = None
    # horizontal-pack provenance: the independent member subgraphs this
    # group packs (None for ordinary dependence-connected groups)
    pack: tuple[frozenset[str], ...] | None = None


class CompiledGraph:
    """Executable produced by :class:`StitchCompiler`.

    Calling it runs the graph group-by-group (each group = one kernel):
    stitched groups through their Pallas callable, the rest through jnp.
    """

    def __init__(self, g: Graph, groups: list[_Group], stats: FusionStats):
        self.graph = g
        self.groups = groups
        self.stats = stats
        self._order = self._schedule()

    def _schedule(self) -> list[_Group]:
        g = self.graph
        owner: dict[str, int] = {}
        for i, grp in enumerate(self.groups):
            for m in grp.members:
                owner[m] = i
        indeg = [0] * len(self.groups)
        succs: list[set[int]] = [set() for _ in self.groups]
        for name, node in g.nodes.items():
            if name not in owner:
                continue
            for o in node.operands:
                if o in owner and owner[o] != owner[name]:
                    if owner[name] not in succs[owner[o]]:
                        succs[owner[o]].add(owner[name])
                        indeg[owner[name]] += 1
        ready = [i for i in range(len(self.groups)) if indeg[i] == 0]
        order: list[int] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for s in sorted(succs[cur]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        assert len(order) == len(self.groups), "cyclic group schedule"
        return [self.groups[i] for i in order]

    def __call__(self, inputs: Mapping[str, jax.Array]) -> dict[str, jax.Array]:
        from .codegen import source_value

        g = self.graph
        env: dict[str, jax.Array] = {}
        for name, node in g.nodes.items():
            if node.is_source():
                env[name] = source_value(node, inputs)
        for grp in self._order:
            if grp.kind == "pallas" and grp.tuned and grp.tuned.callable:
                p = grp.tuned.pattern
                args = [env[i] for i in p.external_inputs]
                outs = grp.tuned.callable(*args)
                for nm, val in zip(p.external_outputs, outs):
                    env[nm] = val
            else:
                # fused-jnp group: evaluate members in topo order
                topo = [n for n in g.topo_order() if n in grp.members]
                for nm in topo:
                    node = g[nm]
                    env[nm] = eval_node(node, [env[o] for o in node.operands], g)
        return {o: env[o] for o in g.outputs}


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------

class StitchCompiler:
    def __init__(
        self,
        hw: HardwareModel = TPU_V5E,
        mode: str = "stitch",
        gen_cfg: GenConfig | None = None,
        execution_based_eval: bool = False,
        use_pallas: bool = True,
        cache=None,
        placement: str = "",
        plan_budget: float | None = None,
        verify: str = "plans",
    ):
        assert mode in ("off", "xla", "stitch")
        assert verify in ("off", "plans", "full")
        self.hw = hw
        self.mode = mode
        self.gen_cfg = gen_cfg or GenConfig()
        # anytime ILP: wall-clock seconds before the fusion-plan solve
        # degrades to the greedy heuristic (None = solve to optimality)
        self.plan_budget = plan_budget
        self.cost = CostModel(hw, reg_budget=self.gen_cfg.reg_budget)
        self.tuner = TemplateTuner(hw, execution_based=execution_based_eval)
        self.use_pallas = use_pallas
        # Optional repro.cache.StitchCache (duck-typed: lookup/insert) — when
        # set, stitch-mode compiles replay cached plans and populate the
        # cache on miss; pattern generation/ILP/tuning run only cold.
        self.cache = cache
        # Mesh/PartitionSpec placement this compile targets (see
        # repro.cache.signature.placement_key).  Part of the cache key: a
        # plan solved for one mesh's shard-local shapes never replays at
        # another.  "" = single-device / unplaced.
        self.placement = placement
        # Static verification level (repro.analysis): "plans" runs the plan
        # verifier post-ILP/pre-tune and refuses ERROR plans; "full" also
        # runs the IR verifier on the graph; "off" skips both.  The same
        # knob gates cache-replay verification (StitchCache.lookup).
        self.verify = verify

    # -- planning -------------------------------------------------------------
    def plan(self, g: Graph) -> tuple[list[FusionPattern], PlanResult | None]:
        if self.mode == "off":
            return [], None
        if self.mode == "xla":
            pats = [
                FusionPattern(g, grp, "xla")
                for grp in xla_like_groups(g)
                if len(grp) >= 2
            ]
            return pats, None
        with obs.span("compile.pattern_gen", cat="compile", graph=g.name) as s:
            patterns = generate_patterns(g, self.gen_cfg, self.hw)
            s.set(patterns=len(patterns),
                  packs=sum(1 for p in patterns
                            if getattr(p, "member_groups", None)))
        pscores = [self.cost.score(p) for p in patterns]
        scratch_budget = self.gen_cfg.scratch_budget
        if scratch_budget is None:
            scratch_budget = self.hw.onchip_budget
        with obs.span("compile.ilp", cat="compile", graph=g.name,
                      patterns=len(patterns)) as s:
            result = solve_fusion_plan(
                g, patterns, [ps.score for ps in pscores],
                budget_seconds=self.plan_budget,
                scratch_requests=[ps.scratch_request for ps in pscores],
                scratch_budget=scratch_budget)
            s.set(method=result.method, chosen=len(result.chosen))
        return result.chosen, result

    # -- static verification (repro.analysis passes 1-2) -----------------------
    def verify_chosen(self, g: Graph, chosen: list[FusionPattern]) -> dict:
        """Run the static verifier on a proposed plan (post-ILP, pre-tune).

        ``verify="plans"`` checks the plan invariants (disjointness, induced
        acyclicity, scratch budget, registry membership); ``verify="full"``
        additionally runs the IR verifier on the graph.  ERROR findings
        raise :class:`repro.analysis.VerificationError` — the compiler
        refuses to tune or execute an illegal plan.  Returns the findings
        summary recorded into :class:`FusionStats`.
        """
        from repro.analysis import (VerificationError, errors, summarize,
                                    verify_graph, verify_plan)

        findings = []
        if self.verify == "full":
            findings += verify_graph(g)
        budget = None
        if self.mode == "stitch":
            budget = self.gen_cfg.scratch_budget
            if budget is None:
                budget = self.hw.onchip_budget
        reg_budget = self.cost.reg_budget if self.mode == "stitch" else None
        findings += verify_plan(g, chosen, require_cover=False,
                                scratch_budget=budget, cost=self.cost,
                                reg_budget=reg_budget)
        if errors(findings):
            obs.event("compile.verify_reject", cat="compile", graph=g.name,
                      codes=sorted({f.code for f in errors(findings)}))
            raise VerificationError(
                f"fusion plan for graph {g.name!r} rejected", findings)
        return summarize(findings)

    # -- modeled whole-graph time (Table 3's perf metric) ----------------------
    def modeled_time(self, g: Graph, groups: list[frozenset[str]]) -> float:
        total = 0.0
        for members in groups:
            if len(members) == 1:
                (m,) = members
                total += self.cost.kernel_time(g, m) + self.hw.launch_latency
            else:
                p = FusionPattern(g, members)
                total += self.cost.fused_time(p) + self.hw.launch_latency
        return total

    def compile(self, g: Graph, *, bypass_cache_lookup: bool = False) -> CompiledGraph:
        with obs.span("compile.graph", cat="compile", graph=g.name,
                      mode=self.mode, placement=self.placement) as osp:
            return self._compile(g, bypass_cache_lookup, osp)

    def _compile(self, g: Graph, bypass_cache_lookup, osp) -> CompiledGraph:
        t0 = _time.perf_counter()
        g.validate()
        cached = self.cache is not None and self.mode == "stitch"
        sig = None
        if cached:
            sig = self.cache.signature_of(g)   # computed once, reused by insert
            if not bypass_cache_lookup:
                hit = self.cache.lookup(g, self, sig=sig)
                if hit is not None:
                    hit.stats.compile_seconds = _time.perf_counter() - t0
                    osp.set(cache="hit", n_kernels=hit.stats.n_kernels)
                    return hit
        chosen, ilp = self.plan(g)
        verify_summary = None
        verify_seconds = 0.0
        if self.verify != "off":
            tv = _time.perf_counter()
            verify_summary = self.verify_chosen(g, chosen)
            verify_seconds = _time.perf_counter() - tv
        covered: set[str] = set()
        for p in chosen:
            covered |= p.members

        groups: list[_Group] = []
        stats = FusionStats(
            mode=self.mode, n_ops=len(g.compute_nodes()), n_kernels=0, ilp=ilp,
            verify=verify_summary, verify_seconds=verify_seconds,
        )

        diag_start = len(self.tuner.diagnostics)
        with obs.span("compile.tune", cat="compile", graph=g.name,
                      patterns=len(chosen)):
            for p in chosen:
                stats.pattern_classes[p.pattern_class] = (
                    stats.pattern_classes.get(p.pattern_class, 0) + 1
                )
                pack = tuple(getattr(p, "member_groups", ())) or None
                if pack:
                    stats.packs += 1
                    stats.packed_subgraphs += len(pack)
                tuned = None
                if self.mode == "stitch" and self.use_pallas:
                    tuned = self.tuner.tune(p)
                if tuned is not None:
                    groups.append(_Group(p.members, "pallas", tuned, pack))
                    stats.pallas_groups += 1
                    stats.scratch_requested += sum(
                        self.cost.scratch_request(p).values()
                    )
                    stats.scratch_allocated += tuned.scratch_plan.allocated
                    if tuned.scratch_plan.allocated:
                        stats.patterns_with_scratch += 1
                else:
                    groups.append(_Group(p.members, "jnp", None, pack))

        # why patterns degraded to fused-jnp during this tuning run
        stats.diagnostics = list(self.tuner.diagnostics[diag_start:])

        # singleton groups for uncovered compute ops
        for node in g.compute_nodes():
            if node.name not in covered:
                groups.append(_Group(frozenset([node.name]), "op"))

        stats.n_kernels = len(groups)
        stats.modeled_time = self.modeled_time(g, [grp.members for grp in groups])
        stats.compile_seconds = _time.perf_counter() - t0
        compiled = CompiledGraph(g, groups, stats)
        osp.set(cache=stats.cache_status, n_kernels=stats.n_kernels,
                modeled_time_s=stats.modeled_time)
        if cached:
            stats.cache_status = "miss"
            self.cache.insert(
                g, compiled, sig=sig, solve_seconds=stats.compile_seconds,
                compiler=self,
            )
            # the plan is now available for replay: every poller's next
            # lookup upgrades — this is the moment a compile "lands"
            obs.event("compile.land", cat="compile", graph=g.name,
                      placement=self.placement,
                      n_kernels=stats.n_kernels,
                      modeled_time_s=stats.modeled_time,
                      compile_seconds=stats.compile_seconds)
        return compiled
