"""FusionStitching core — the paper's contribution as a composable library.

Public surface:
    GraphBuilder / Graph / OpNode     — StitchIR
    generate_patterns / GenConfig     — §4.2 pattern search
    CostModel / HardwareModel         — §4.3 scoring (V100 + TPU_V5E presets)
    solve_fusion_plan                 — §4.1 ILP + cycle cuts
    Template / parse_template         — §5.2 implementation templates
    ScratchAllocator                  — §5.4 dominance-tree VMEM reuse
    TemplateTuner                     — Alg. 3 kernel tuning
    StitchCompiler / CompiledGraph    — end-to-end optimize-and-execute
"""

from .cost import CostModel, HardwareModel, TPU_V5E, V100
from .fusiongen import GenConfig, exploratory_fusion, generate_patterns, multi_step_substitution, packing_fusion, substitution_fusion
from .ilp import ILPSolver, PlanResult, greedy_fusion_plan, solve_fusion_plan
from .ir import Graph, GraphBuilder, OpKind, OpNode, ReduceKind
from .pattern import FusionPattern, PackPattern, PatternClass, contraction_creates_cycle
from .scratch import ScratchAllocator, ScratchPlan, dominator_tree, post_dominates
from .templates import Template, parse_template
from .tuner import TemplateTuner, TunedKernel, generate_templates

# compiler/codegen import jax at module level; everything above is pure
# Python.  Loading them lazily (PEP 562) keeps `import repro.core` — and
# with it the repro.analysis static verifier — usable in a jax-free
# process, e.g. the offline cache audit in CI.
_LAZY = {
    "CompiledGraph": ".compiler", "FusionStats": ".compiler",
    "StitchCompiler": ".compiler", "xla_like_groups": ".compiler",
    "build_reference_fn": ".codegen", "build_per_op_fns": ".codegen",
    "emit_source": ".codegen",
}


def __getattr__(name):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module
    return getattr(import_module(submodule, __name__), name)

__all__ = [
    "Graph", "GraphBuilder", "OpNode", "OpKind", "ReduceKind",
    "FusionPattern", "PackPattern", "PatternClass",
    "contraction_creates_cycle",
    "GenConfig", "generate_patterns", "substitution_fusion",
    "multi_step_substitution", "exploratory_fusion", "packing_fusion",
    "CostModel", "HardwareModel", "TPU_V5E", "V100",
    "ILPSolver", "PlanResult", "solve_fusion_plan", "greedy_fusion_plan",
    "Template", "parse_template",
    "ScratchAllocator", "ScratchPlan", "dominator_tree", "post_dominates",
    "TemplateTuner", "TunedKernel", "generate_templates",
    "StitchCompiler", "CompiledGraph", "FusionStats", "xla_like_groups",
    "build_reference_fn", "build_per_op_fns", "emit_source",
]
